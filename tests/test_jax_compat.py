"""JAX compat shims (`repro.jax_compat`) resolve on the installed JAX.

The model/runtime stack targets the post-0.6 sharding API; the environment
pins 0.4.37, which has none of it.  These tests pin the shim contract on
whatever JAX is installed: every symbol resolves, mesh construction and
activation work without the new-API names, and `shard` / `logical` resolve
PartitionSpecs against the active mesh through the version-guarded
``get_abstract_mesh`` fallback.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import jax_compat as jc


def test_axis_type_and_make_mesh_resolve():
    # importing AxisType must never fail, installed version regardless
    assert hasattr(jc.AxisType, "Auto")
    mesh = jc.make_mesh((1, 1), ("data", "model"),
                        axis_types=(jc.AxisType.Auto,) * 2)
    assert mesh.axis_names == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_get_abstract_mesh_tracks_mesh_context():
    assert jc.get_abstract_mesh() is None
    mesh = jc.make_mesh((1, 1), ("data", "model"))
    with jc.set_mesh(mesh):
        m = jc.get_abstract_mesh()
        assert m is not None
        assert tuple(m.axis_names) == ("data", "model")
    assert jc.get_abstract_mesh() is None


def test_shard_and_logical_work_without_new_api_symbols():
    from repro.parallel.sharding import logical, shard

    x = jnp.ones((4, 8))
    # no mesh in scope: shard is the identity (the arch-smoke path)
    np.testing.assert_array_equal(np.asarray(shard(x, "batch", None)),
                                  np.asarray(x))
    mesh = jc.make_mesh((1, 1), ("data", "model"))
    with jc.set_mesh(mesh):
        spec = logical("batch", "mlp")
        # the ("pod", "data") batch rule prunes to the in-mesh axes
        assert tuple(spec) == (("data",), "model")
        y = jax.jit(lambda t: shard(t, "batch", "mlp"))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_mesh_module_imports_and_builds_host_mesh():
    # the seed failed at `from jax.sharding import AxisType` module scope
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    assert "data" in mesh.axis_names and "model" in mesh.axis_names


def test_tree_as_shardings_wraps_specs_for_jit():
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jc.make_mesh((1, 1), ("data", "model"))
    tree = {"w": P("data", None), "b": None, "n": (P("model"), None)}
    out = jc.tree_as_shardings(mesh, tree)
    assert isinstance(out["w"], NamedSharding)
    assert out["b"] is None
    assert isinstance(out["n"][0], NamedSharding) and out["n"][1] is None
    # the wrapped tree is jit-accepted on every version (the 0.4.x failure
    # mode was jit rejecting raw PartitionSpecs)
    f = jax.jit(lambda x: x + 1, in_shardings=out["w"], out_shardings=out["w"])
    np.testing.assert_array_equal(np.asarray(f(jnp.zeros((2, 2)))),
                                  np.ones((2, 2)))


def test_pcast_and_shard_map_resolve():
    from jax.sharding import PartitionSpec as P

    assert np.asarray(jc.pcast(jnp.ones(3), ("data",))).sum() == 3
    mesh = jc.make_mesh((1,), ("stage",))
    f = jc.shard_map(lambda x: x * 2, mesh=mesh, in_specs=(P("stage"),),
                     out_specs=P("stage"))
    np.testing.assert_array_equal(np.asarray(f(jnp.arange(4.0))),
                                  np.arange(4.0) * 2)


def test_set_mesh_usable_as_context_manager():
    mesh = jc.make_mesh((1, 1), ("data", "model"))
    with jc.set_mesh(mesh):
        pass  # old JAX: the Mesh itself; new JAX: jax.set_mesh's manager
    with pytest.raises(ValueError):
        jc.make_mesh((7, 3), ("a", "b"))  # device count mismatch still raises
