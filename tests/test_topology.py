"""Interconnect layer: routing correctness, PBR tables, builders."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import repro.core  # noqa: F401
from repro.core import topology as T


def _random_connected(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 14))
    kinds = [T.SWITCH] * n
    links = []
    for i in range(1, n):  # random spanning tree
        j = int(rng.integers(0, i))
        links.append(T.LinkSpec(i, j, 64_000, 26_000))
    for _ in range(int(rng.integers(0, n))):  # extra edges
        a, b = rng.integers(0, n, 2)
        if a != b:
            links.append(T.LinkSpec(int(a), int(b), 64_000, 26_000))
    return T.Topology(np.asarray(kinds, np.int64), links, name="rand")


@given(st.integers(0, 5000))
@settings(max_examples=40, deadline=None)
def test_routes_reach_destination_and_are_shortest(seed):
    topo = _random_connected(seed)
    g = topo.build()
    n = topo.n_nodes
    # BFS distances as oracle
    adj = {i: set() for i in range(n)}
    for ls in topo.links:
        adj[ls.a].add(ls.b)
        adj[ls.b].add(ls.a)
    for src in range(min(n, 5)):
        dist = {src: 0}
        frontier = [src]
        while frontier:
            nxt = []
            for u in frontier:
                for v in adj[u]:
                    if v not in dist:
                        dist[v] = dist[u] + 1
                        nxt.append(v)
            frontier = nxt
        for dst in range(n):
            path = g.route(src, dst)
            assert path[0] == src and path[-1] == dst
            assert len(path) - 1 == dist[dst]  # hop-count shortest
            for u, v in zip(path[:-1], path[1:]):
                assert v in adj[u]  # every hop is a real link


@given(st.integers(0, 5000))
@settings(max_examples=20, deadline=None)
def test_pbr_table_consistent_with_routes(seed):
    """Hop-by-hop forwarding via per-switch PBR tables reproduces the
    interconnect layer's route (ESF: switches build tables from graph data)."""
    topo = _random_connected(seed)
    g = topo.build()
    n = topo.n_nodes
    rng = np.random.default_rng(seed)
    for _ in range(5):
        src, dst = rng.integers(0, n, 2)
        node, hops = int(src), 0
        while node != dst and hops <= n:
            node = int(g.routing_table(node)[dst])
            hops += 1
        assert node == int(dst)
        assert hops == g.hops(int(src), int(dst))


@pytest.mark.parametrize("kind", list(T.TOPOLOGY_BUILDERS))
def test_builders_wellformed(kind):
    n_pairs = 8
    topo = (T.spine_leaf(n_pairs, per_leaf=4) if kind == "spine_leaf"
            else T.TOPOLOGY_BUILDERS[kind](n_pairs))
    g = topo.build()
    reqs, mems = topo.requesters(), topo.memories()
    assert len(reqs) == n_pairs and len(mems) == n_pairs
    for r in reqs:
        for m in mems:
            path = g.route(int(r), int(m))
            assert path[0] == r and path[-1] == m
            # endpoints only at the ends; interior is switches
            assert all(topo.kinds[u] == T.SWITCH for u in path[1:-1])


def test_route_alternatives_are_distinct_and_equal_cost():
    topo = T.spine_leaf(8, n_spines=2, per_leaf=4)
    g = topo.build()
    r, m = int(topo.requesters()[0]), int(topo.memories()[0])
    k = g.n_route_alternatives(r, m)
    assert k >= 2
    paths = {tuple(g.route(r, m, alt=a)) for a in range(k)}
    assert len(paths) == k
    assert len({len(p) for p in paths}) == 1  # equal cost
