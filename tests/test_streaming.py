"""Streaming windowed engine: windowed == monolithic bit-for-bit at every
window size (the correctness contract), across turnaround/row/zero-byte
tables, stochastic reliability (sampled replay bursts + retraining markers),
and fork/join DAGs; streamed telemetry folds equal the monolithic counters
and sketch; chunk-stream contracts are enforced; protocol-state threading
makes chunked SF / coherence runs exact."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st  # optional-hypothesis shim

import jax.numpy as jnp

import repro.core  # noqa: F401  (x64)
from repro.core import topology as T
from repro.core.coherence_traffic import (CoherenceFabricSpec,
                                          CoherenceStream, coherence_issue,
                                          lower_coherence)
from repro.core.devices import RequesterSpec, build_workload
from repro.core.engine import (Channels, Hops, SimOptions, empty_carry,
                               simulate, simulate_auto)
from repro.core.link_layer import FlitConfig
from repro.core.snoop_filter import (CacheConfig, SFConfig, make_skewed_stream,
                                     sf_init_state, simulate_sf)
from repro.core.streaming import StreamState, simulate_stream, stream_windows
from repro.core.telemetry import (channel_telemetry, sketch_new,
                                  sketch_quantiles, sketch_update)

WINDOWS = (1, 3, 7, 1000)


# ---------------------------------------------------------------------------
# case builders (mirroring test_engine / test_link_reliability families)
# ---------------------------------------------------------------------------

def _random_case(seed, with_rows=True, with_turnaround=True, zero_bytes=True):
    rng = np.random.default_rng(seed)
    n, h, c = int(rng.integers(3, 40)), int(rng.integers(1, 7)), int(rng.integers(1, 6))
    bw = rng.integers(10, 100, c).astype(np.int64) * 1000
    turn = (np.where(rng.random(c) < .5, rng.integers(100, 5000, c), 0)
            if with_turnaround else np.zeros(c)).astype(np.int64)
    rowm = np.zeros(c, bool)
    if with_rows:
        rowm[-1] = True
    ch = Channels(jnp.asarray(bw), jnp.asarray(turn),
                  jnp.asarray(np.where(rowm, 1000, 0).astype(np.int64)),
                  jnp.asarray(np.where(rowm, 9000, 0).astype(np.int64)))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nbytes = rng.integers(1, 300, (n, h)).astype(np.int64)
    if zero_bytes:
        nbytes = np.where(rng.random((n, h)) < 0.2, 0, nbytes)
    dirn = rng.integers(0, 2, (n, h)).astype(np.int8)
    row = np.where((chan == c - 1) & rowm[-1],
                   rng.integers(0, 3, (n, h)), -1).astype(np.int32)
    fixed = rng.integers(0, 2000, (n, h)).astype(np.int64)
    valid = rng.random((n, h)) < .85
    issue = np.sort(rng.integers(0, 5000, n)).astype(np.int64)
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes), jnp.asarray(dirn),
                jnp.asarray(row), jnp.asarray(fixed), jnp.asarray(valid),
                jnp.asarray(valid))
    return hops, ch, issue


def _reliability_case(seed):
    """Randomized replay/retraining tables over mixed byte-exact and flit
    channels — link-down markers included (zero-byte retrain hops)."""
    rng = np.random.default_rng(seed)
    n, h, c = int(rng.integers(3, 24)), int(rng.integers(1, 6)), \
        int(rng.integers(2, 6))
    bw = rng.integers(10, 100, c).astype(np.int64) * 1000
    turn = np.where(rng.random(c) < .5,
                    rng.integers(100, 5000, c), 0).astype(np.int64)
    fsize = rng.choice([0, 68, 256], c).astype(np.int64)
    fpay = np.where(fsize == 68, 64,
                    np.where(fsize == 256, 236, 0)).astype(np.int64)
    ch = Channels(jnp.asarray(bw), jnp.asarray(turn),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  flit_size=jnp.asarray(fsize),
                  flit_payload=jnp.asarray(fpay),
                  replay_ppm=jnp.asarray(np.zeros(c, np.int64)))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nbytes = rng.integers(0, 1200, (n, h)).astype(np.int64)
    valid = rng.random((n, h)) < .85
    extra = np.where(rng.random((n, h)) < .3,
                     rng.integers(0, 8, (n, h)) * 256, 0).astype(np.int64)
    retrain = np.where(rng.random((n, h)) < .2,
                       rng.integers(1, 4, (n, h)) * 100_000, 0).astype(np.int64)
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes),
                jnp.asarray(rng.integers(0, 2, (n, h)).astype(np.int8)),
                jnp.asarray(np.full((n, h), -1, np.int32)),
                jnp.asarray(rng.integers(0, 2000, (n, h)).astype(np.int64)),
                jnp.asarray(valid), jnp.asarray(valid),
                extra_wire_bytes=jnp.asarray(extra),
                retrain_after_ps=jnp.asarray(retrain))
    issue = np.sort(rng.integers(0, 5000, n)).astype(np.int64)
    return hops, ch, issue


def _join_case(seed, layers=3):
    """Random hop tables + a layered join DAG (varying arity, one waiter on
    an empty group)."""
    rng = np.random.default_rng(seed)
    n, h, c = int(rng.integers(12, 36)), int(rng.integers(2, 5)), int(rng.integers(2, 5))
    bw = rng.integers(10, 100, c).astype(np.int64) * 1000
    ch = Channels(jnp.asarray(bw),
                  jnp.asarray(np.where(rng.random(c) < .4,
                                       rng.integers(100, 4000, c), 0)
                              .astype(np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)),
                  jnp.asarray(np.zeros(c, np.int64)))
    chan = rng.integers(0, c, (n, h)).astype(np.int32)
    nbytes = rng.integers(1, 400, (n, h)).astype(np.int64)
    nbytes = np.where(rng.random((n, h)) < 0.15, 0, nbytes)
    valid = rng.random((n, h)) < .85
    jid = np.full(n, -1, np.int32)
    jwait = np.full(n, -1, np.int32)
    jarity = np.zeros(n, np.int32)
    bounds = np.sort(rng.choice(np.arange(1, n), layers, replace=False))
    layer_rows = np.split(np.arange(n), bounds)
    grp = 0
    for up, dn in zip(layer_rows[:-1], layer_rows[1:]):
        for w in dn:
            if rng.random() < 0.5:
                members = up[rng.random(up.shape[0]) < 0.5]
                members = members[jid[members] < 0]
                if members.size == 0:
                    continue
                jid[members] = grp
                jwait[w] = grp
                jarity[w] = members.size
                grp += 1
    free = np.nonzero(jwait < 0)[0]
    if free.size:
        jwait[free[-1]] = grp
        jarity[free[-1]] = 0
    hops = Hops(jnp.asarray(chan), jnp.asarray(nbytes),
                jnp.asarray(rng.integers(0, 2, (n, h)).astype(np.int8)),
                jnp.asarray(np.full((n, h), -1, np.int32)),
                jnp.asarray(rng.integers(0, 2000, (n, h)).astype(np.int64)),
                jnp.asarray(valid), jnp.asarray(valid),
                join_id=jnp.asarray(jid), join_wait=jnp.asarray(jwait),
                join_arity=jnp.asarray(jarity))
    issue = np.sort(rng.integers(0, 5000, n)).astype(np.int64)
    return hops, ch, issue


def _stream_check(hops, ch, issue, window):
    """Windowed run == monolithic run, bit for bit: every valid item's
    (start, depart, arrive) exactly once, every row's completion and gated
    first-hop arrival."""
    mono = simulate(hops, ch, jnp.asarray(issue))
    assert bool(mono.converged)
    out = simulate_stream(stream_windows(hops, issue, window), ch,
                          collect_schedule=True)
    col = out.collected
    v = np.asarray(hops.valid)
    n, h = v.shape
    assert out.n_rows == n

    r = col["item_row"].astype(np.int64)
    k = col["item_hop"].astype(np.int64)
    got = set(zip(r.tolist(), k.tolist()))
    assert len(got) == r.size                      # folded exactly once
    assert got == {(int(i), int(j)) for i, j in zip(*np.nonzero(v))}
    ms, md, ma = map(np.asarray, (mono.start, mono.depart, mono.arrive))
    assert np.array_equal(col["item_start"], ms[r, k])
    assert np.array_equal(col["item_depart"], md[r, k])
    assert np.array_equal(col["item_arrive"], ma[r, k])

    rr = col["row_id"].astype(np.int64)
    assert np.array_equal(np.sort(rr), np.arange(n))   # every row retires once
    assert np.array_equal(col["row_complete"], np.asarray(mono.complete)[rr])
    gr = col["gate_row"].astype(np.int64)
    assert np.array_equal(col["gate_arrive0"], ma[gr, 0])
    return mono, out


# ---------------------------------------------------------------------------
# the correctness contract: windowed == monolithic at any window size
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.sampled_from(WINDOWS))
@settings(max_examples=25, deadline=None)
def test_stream_equals_monolithic_random(seed, window):
    hops, ch, issue = _random_case(seed)
    _stream_check(hops, ch, issue, window)


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("window", (1, 5))
def test_stream_equals_monolithic_reliability(seed, window):
    hops, ch, issue = _reliability_case(seed)
    _stream_check(hops, ch, issue, window)


@given(st.integers(0, 10_000), st.sampled_from(WINDOWS))
@settings(max_examples=25, deadline=None)
def test_stream_equals_monolithic_joins(seed, window):
    hops, ch, issue = _join_case(seed)
    _stream_check(hops, ch, issue, window)


def test_stream_equals_monolithic_built_workload_markers():
    """The full build path: stochastic flit reliability whose retraining
    stalls insert full-duplex mirror markers into the hop table."""
    topo = T.with_flit(T.single_bus(n_mems=4, bw_MBps=128_000),
                       FlitConfig("flit256", ber=3e-4,
                                  reliability="stochastic", rel_seed=7,
                                  retrain_threshold=2, retrain_ps=1_000_000))
    spec = RequesterSpec(node=0, n_requests=150, targets=[2, 3, 4, 5],
                         read_ratio=0.5, issue_interval_ps=300,
                         payload_bytes=944, seed=3)
    wl = build_workload(topo.build(), [spec], warmup_frac=0.0)
    assert np.asarray(wl.hops.retrain_after_ps).any()
    _stream_check(wl.hops, wl.channels, np.asarray(wl.issue_ps), 17)


# ---------------------------------------------------------------------------
# streamed telemetry fold == monolithic counters and sketch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", (1, 6))
def test_stream_telemetry_matches_monolithic(window):
    hops, ch, issue = _reliability_case(3)
    mono, out = _stream_check(hops, ch, issue, window)
    tel = channel_telemetry(hops, ch, mono)
    acc = out.telemetry
    for sf, mf in (("payload_bytes", "payload_bytes"),
                   ("wire_bytes", "wire_bytes"), ("busy_ps", "busy_ps"),
                   ("wait_ps", "wait_ps")):
        assert np.array_equal(np.asarray(getattr(acc, sf)),
                              np.asarray(getattr(tel, mf))), sf
    h = np.asarray(hops.valid).shape[1]
    lat = np.asarray(mono.arrive)[:, h] - issue
    sk = sketch_update(sketch_new(), jnp.asarray(lat),
                       mask=jnp.ones(lat.shape, bool))
    assert np.array_equal(np.asarray(sketch_quantiles(acc.sketch)),
                          np.asarray(sketch_quantiles(sk)))
    assert int(acc.n_retired) == lat.shape[0]
    s = out.summary()
    assert s["n_retired"] == lat.shape[0] and s["windows"] == out.windows


# ---------------------------------------------------------------------------
# chunk-stream contracts
# ---------------------------------------------------------------------------

def test_stream_windows_never_split_join_groups():
    hops, ch, issue = _join_case(11)
    for w in (1, 2, 3):
        for ck, _ in stream_windows(hops, issue, w):
            jid = np.asarray(ck.join_id)
            jw = np.asarray(ck.join_wait)
            ja = np.asarray(ck.join_arity)
            for g in np.unique(jw[jw >= 0]):
                # every waiter's arity is satisfied inside its own chunk
                assert (jid == g).sum() == ja[jw == g].max()


def test_out_of_order_chunk_stream_rejected():
    hops, ch, issue = _random_case(1)
    chunks = list(stream_windows(hops, issue, 10))[::-1]
    if len(chunks) > 1:
        with pytest.raises(ValueError, match="out of order"):
            simulate_stream(chunks, ch)


def test_mixed_layout_chunk_stream_rejected():
    h1, ch, i1 = _random_case(2)
    h2, _, i2 = _reliability_case(2)
    with pytest.raises(ValueError, match="layout"):
        simulate_stream([(h1, i1 - i1.min()), (h2, i2 + i1.max())],
                        Channels(ch.bw_MBps, ch.turnaround_ps,
                                 ch.row_hit_ps, ch.row_miss_ps))


def test_stream_state_resumes_across_calls():
    """Two `simulate_stream` calls with the state handed across equal one
    call when the split lands on a quiescent boundary (each call drains its
    own rows, so a split is exact iff nothing later could have contended —
    here the second segment issues after a gap longer than any makespan)."""
    hops, ch, issue = _random_case(33)
    early = list(stream_windows(hops, issue, 4))
    late = list(stream_windows(hops, issue + 2_000_000_000, 4))
    one = simulate_stream(early + late, ch)
    state = StreamState(ch)
    a = simulate_stream(early, ch, state)
    b = simulate_stream(late, ch, state)
    assert b.n_rows == one.n_rows
    assert int(b.telemetry.n_retired) == int(one.telemetry.n_retired)
    assert np.array_equal(np.asarray(b.telemetry.busy_ps),
                          np.asarray(one.telemetry.busy_ps))
    assert np.array_equal(np.asarray(sketch_quantiles(b.telemetry.sketch)),
                          np.asarray(sketch_quantiles(one.telemetry.sketch)))


# ---------------------------------------------------------------------------
# engine carry API
# ---------------------------------------------------------------------------

def test_empty_carry_is_identity():
    hops, ch, issue = _random_case(5)
    base = simulate(hops, ch, jnp.asarray(issue))
    c = int(ch.bw_MBps.shape[0])
    seeded = simulate(hops, ch, jnp.asarray(issue),
                      carry=empty_carry(c))
    for f in ("start", "depart", "arrive", "complete"):
        assert np.array_equal(np.asarray(getattr(base, f)),
                              np.asarray(getattr(seeded, f))), f
    hj, chj, ij = _join_case(5)
    bj = simulate(hj, chj, jnp.asarray(ij))
    sj = simulate(hj, chj, jnp.asarray(ij),
                  carry=empty_carry(int(chj.bw_MBps.shape[0]),
                                    int(hj.channel.shape[0])))
    assert np.array_equal(np.asarray(bj.complete), np.asarray(sj.complete))


def test_simulate_auto_check_flag_skips_fallback():
    hops, ch, issue = _random_case(7)
    # forced non-convergence: the default falls back to the oracle ...
    sched, used = simulate_auto(hops, ch, jnp.asarray(issue),
                                SimOptions(max_rounds=1))
    assert used and bool(sched.converged)
    # ... check='off' returns the raw fixpoint without the host sync
    raw, used = simulate_auto(hops, ch, jnp.asarray(issue),
                              SimOptions(max_rounds=1, check="off"))
    assert not used and not bool(raw.converged)
    # on a converged run check='off' is the same schedule
    full, used = simulate_auto(hops, ch, jnp.asarray(issue),
                               SimOptions(check="off"))
    ref, _ = simulate_auto(hops, ch, jnp.asarray(issue))
    assert not used
    assert np.array_equal(np.asarray(full.complete), np.asarray(ref.complete))


# ---------------------------------------------------------------------------
# protocol-state threading: chunked SF / coherence == monolithic
# ---------------------------------------------------------------------------

def test_sf_state_threading_bitexact():
    cfg = SFConfig(capacity=16, footprint_lines=256, policy="lru")
    ccfg = CacheConfig(capacity=8)
    addr, wr, _ = make_skewed_stream(400, 256, seed=3)
    rid = jnp.asarray(np.arange(400) % 3, jnp.int32)
    mono, mev = simulate_sf(addr, wr, rid, cfg, ccfg, n_requesters=3,
                            return_events=True)
    st_ = sf_init_state(cfg, ccfg, 3)
    lats, fabs = [], []
    for lo in range(0, 400, 97):
        hi = min(lo + 97, 400)
        r, ev, st_ = simulate_sf(addr[lo:hi], wr[lo:hi], rid[lo:hi], cfg,
                                 ccfg, n_requesters=3, return_events=True,
                                 init_state=st_, return_state=True)
        lats.append(np.asarray(r.latency_ps))
        fabs.append(np.asarray(ev.fab_issue_ps))
    assert np.array_equal(np.concatenate(lats), np.asarray(mono.latency_ps))
    assert np.array_equal(np.concatenate(fabs), np.asarray(mev.fab_issue_ps))
    assert int(st_.bisnp) == int(mono.bisnp_events)
    assert int(jnp.max(st_.clock)) == int(mono.total_time_ps)


def test_coherence_stream_matches_monolithic():
    kinds = [T.SWITCH, T.REQUESTER, T.REQUESTER, T.MEMORY]
    links = [T.LinkSpec(i, 0, 64_000, 26_000) for i in (1, 2, 3)]
    graph = T.Topology(np.asarray(kinds, np.int64), links,
                       name="star").build()
    spec = CoherenceFabricSpec(dev_node=3, req_nodes=(1, 2))
    sf_cfg = SFConfig(capacity=16, footprint_lines=256, policy="lru")
    ccfg = CacheConfig(capacity=8)
    addr, wr, rid = make_skewed_stream(420, 256, write_ratio=0.3,
                                       n_requesters=2, seed=4)
    res, ev = simulate_sf(addr, wr, rid, sf_cfg, ccfg, n_requesters=2,
                          return_events=True)
    low = lower_coherence(graph, spec, sf_cfg, np.asarray(addr),
                          np.asarray(wr), np.asarray(rid), ev,
                          fanout="chain")
    cs = CoherenceStream(addr, wr, rid, sf_cfg, ccfg, graph, spec,
                         chunk=101, n_requesters=2, fanout="chain")
    ch = cs.channels()
    mono = simulate(low.hops, ch, coherence_issue(low, ev.fab_issue_ps))
    assert bool(mono.converged)
    out = simulate_stream(cs, ch, collect_schedule=True)
    col = out.collected
    ma = np.asarray(mono.arrive)
    r = col["item_row"].astype(np.int64)
    k = col["item_hop"].astype(np.int64)
    got = set(zip(r.tolist(), k.tolist()))
    assert got == {(int(i), int(j))
                   for i, j in zip(*np.nonzero(np.asarray(low.hops.valid)))}
    assert np.array_equal(col["item_start"], np.asarray(mono.start)[r, k])
    assert np.array_equal(col["item_depart"], np.asarray(mono.depart)[r, k])
    assert np.array_equal(col["item_arrive"], ma[r, k])
    rr = col["row_id"].astype(np.int64)
    assert np.array_equal(col["row_complete"], np.asarray(mono.complete)[rr])
    assert cs.n_done == 420 and out.n_rows == low.hops.channel.shape[0]
