"""Logical sharding rules: mesh pruning, divisibility pruning, fabric model."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.core  # noqa: F401
from repro.core.autotune import DEFAULT_CANDIDATES, WorkloadDims, autotune
from repro.core.fabric_model import (TPUFabric, analytic_ring_seconds,
                                     predict_collective)
from repro.parallel.sharding import (ShardingRules, prune_spec_for_shape)


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_prune_spec_divisibility():
    mesh = _FakeMesh({"data": 16, "model": 16, "pod": 2})
    # 51865 vocab doesn't divide 16 -> pruned; 4096 does -> kept
    s = prune_spec_for_shape(P("model", "data"), (51865, 4096), mesh)
    assert s == P(None, "data")
    # batch=1 can't shard over ('pod','data')=32
    s = prune_spec_for_shape(P(("pod", "data"), None), (1, 10), mesh)
    assert s == P(None, None)
    s = prune_spec_for_shape(P(("pod", "data"), None), (256, 10), mesh)
    assert s == P(("pod", "data"), None)


def test_rules_override():
    r = ShardingRules().with_overrides(seq="model")
    assert r.rules["seq"] == "model"
    assert ShardingRules().rules["seq"] is None


def test_fabric_ring_matches_alpha_beta():
    fab = TPUFabric(nx=4, ny=4)
    g = fab.build()
    est = predict_collective(fab, g, "all_reduce", "x", 8 << 20)
    ana = analytic_ring_seconds(8 << 20, 4)
    assert abs(est.seconds - ana) / ana < 0.05


def test_all_to_all_shows_contention():
    fab = TPUFabric(nx=8, ny=8)
    g = fab.build()
    est = predict_collective(fab, g, "all_to_all", "x", 32 << 20)
    naive = (32 << 20) / 8 * 7 / (50_000 * 1e6 * 2)
    assert est.seconds > 1.5 * naive  # torus contention is real


def test_autotune_filters_infeasible_and_ranks():
    dims = WorkloadDims(n_layers=32, d_model=4096, d_ff=14336, n_heads=32,
                        n_kv=8, head_dim=128, vocab=128256, batch=256,
                        seq=4096)
    scores = autotune(dims, TPUFabric(16, 16))
    assert scores, "no feasible layout"
    assert scores[0].step_s <= scores[-1].step_s
    # ddp (unsharded state) must not be the winner for an 8B model
    assert scores[0].layout.name != "ddp"
